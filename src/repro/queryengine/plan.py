"""Logical query plans as operator DAGs, partitioned into subQs.

Mirrors the paper's §3.1/§4.1 structures:

* An :class:`Operator` is one node of the logical query plan (LQP) with its
  *true* output cardinality (rows, bytes) and the compile-time *estimate*
  produced by a simulated cost-based optimizer (CBO) whose error grows with
  operator depth — exactly the gap Spark AQE exploits at runtime.
* A :class:`SubQ` is a group of logical operators that maps 1:1 to a query
  stage (QS) when the plan is physically compiled: stage boundaries sit at
  data-exchange edges (shuffle / broadcast).  Scan-rooted groups and
  join/aggregate-rooted groups are the two families that occur.
* A :class:`Query` is a DAG of subQs executed in topological order, plus the
  flattened operator DAG used by the GTN plan embedder.

Cardinality semantics: ``rows``/``bytes`` are ground truth (known only to the
environment and revealed per-stage at runtime); ``est_rows``/``est_bytes``
are what the compile-time optimizer believes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["OP_TYPES", "Operator", "SubQ", "Query", "topo_order"]

# Operator vocabulary for one-hot encoding (paper §4.3: operator type one-hot).
OP_TYPES = [
    "scan",
    "filter",
    "project",
    "join",
    "agg",
    "sort",
    "exchange",
    "limit",
    "expand",
    "window",
]
_OP_INDEX = {t: i for i, t in enumerate(OP_TYPES)}


@dataclasses.dataclass
class Operator:
    op_id: int
    op_type: str
    children: List[int]                      # op_ids within the same Query
    rows: float = 0.0                        # true output cardinality
    bytes: float = 0.0                       # true output size (bytes)
    est_rows: float = 0.0                    # CBO estimate
    est_bytes: float = 0.0
    pred_tokens: Tuple[str, ...] = ()        # predicate tokens (hashed embed)

    @property
    def type_index(self) -> int:
        return _OP_INDEX[self.op_type]


@dataclasses.dataclass
class SubQ:
    """A group of operators ≙ one query stage once physically planned."""

    sq_id: int
    op_ids: List[int]                        # member operators (topological)
    children: List[int]                      # upstream subQ ids (exchange in)
    kind: str                                # "scan" | "join" | "agg"
    root_op: int                             # op_id producing the stage output
    # --- simulator-facing static features ---------------------------------
    table: Optional[str] = None              # for scans
    # Per-input true/estimated sizes, aligned with ``children`` for non-scan
    # stages; for scans these describe the table read.
    input_rows: Tuple[float, ...] = ()
    input_bytes: Tuple[float, ...] = ()
    est_input_rows: Tuple[float, ...] = ()
    est_input_bytes: Tuple[float, ...] = ()
    # Output (== root operator output).
    out_rows: float = 0.0
    out_bytes: float = 0.0
    est_out_rows: float = 0.0
    est_out_bytes: float = 0.0
    # Work shape knobs used by the analytical cost model.
    cpu_weight: float = 1.0                  # relative CPU work per byte
    skew: float = 0.0                        # partition-size skew in [0, 1)
    depth: int = 0                           # distance from the leaves


@dataclasses.dataclass
class Query:
    qid: str
    ops: List[Operator]
    subqs: List[SubQ]
    benchmark: str = ""                      # "tpch" | "tpcds"
    template: int = 0

    # -- structure helpers --------------------------------------------------
    def topo_subqs(self) -> List[int]:
        return topo_order([(s.sq_id, s.children) for s in self.subqs])

    def subq_depths(self) -> List[int]:
        depth = {}
        for sid in self.topo_subqs():
            ch = self.subqs[sid].children
            depth[sid] = 0 if not ch else 1 + max(depth[c] for c in ch)
        return [depth[s.sq_id] for s in self.subqs]

    @property
    def n_subqs(self) -> int:
        return len(self.subqs)

    def op_adjacency(self) -> np.ndarray:
        """(n_ops, n_ops) directed adjacency (child -> parent) for the GTN."""
        n = len(self.ops)
        A = np.zeros((n, n), np.float32)
        for op in self.ops:
            for c in op.children:
                A[c, op.op_id] = 1.0
        return A

    def subq_ops(self, sq_id: int) -> List[Operator]:
        return [self.ops[i] for i in self.subqs[sq_id].op_ids]


def topo_order(nodes: Sequence[Tuple[int, Sequence[int]]]) -> List[int]:
    """Kahn topological order of (id, deps) pairs; deterministic."""
    deps = {i: set(ch) for i, ch in nodes}
    order: List[int] = []
    ready = sorted([i for i, d in deps.items() if not d])
    children_of: Dict[int, List[int]] = {i: [] for i, _ in nodes}
    for i, ch in nodes:
        for c in ch:
            children_of[c].append(i)
    while ready:
        i = ready.pop(0)
        order.append(i)
        for p in sorted(children_of[i]):
            deps[p].discard(i)
            if not deps[p]:
                ready.append(p)
        ready.sort()
    if len(order) != len(deps):
        raise ValueError("cycle in subQ DAG")
    return order


def cbo_estimate(true_value: float, depth: int, rng: np.random.Generator,
                 sigma0: float = 0.25) -> float:
    """Simulated CBO cardinality estimate.

    Multiplicative log-normal error whose spread grows with operator depth
    (selectivity estimation compounds through joins) — the well-known
    exponential error growth of cardinality estimation.
    """
    sigma = sigma0 * (1.0 + 0.6 * depth)
    err = math.exp(rng.normal(0.0, sigma))
    return max(1.0, true_value * err)
