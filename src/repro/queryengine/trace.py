"""Training-trace collection (paper §6: 50k parametric queries, LHS configs).

Each trace row pairs one (query, configuration) execution with the stage- and
query-level targets the three model families learn:

* subQ  (compile time): analytical latency + IO per stage, CBO statistics,
  β = 0, γ = 0 (paper §4.3 "adapting to different modeling targets").
* QS    (runtime): analytical latency + IO per stage, *true* statistics,
  observed partition-size distribution β, contention γ.
* L̄QP  (runtime): end-to-end latency + IO of the whole (collapsed) plan.

Configurations are Latin-Hypercube sampled in the unit cube over the full 19
parameter space (θc ⊕ θp ⊕ θs), matching the paper's data-collection setup.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.models.features import contention_gamma
from ..core.tuning.spark_space import (theta_c_space, theta_p_space,
                                       theta_s_space)
from .plan import Query
from .simulator import CostModel, DEFAULT_COST, simulate_query

__all__ = ["TraceSet", "collect_traces"]


@dataclasses.dataclass
class TraceSet:
    """Flat arrays over (query × config × subQ) samples."""

    # Per-sample indices into ``queries``.
    queries: List[Query]
    query_idx: np.ndarray          # (S,) int — which query
    subq_idx: np.ndarray           # (S,) int — which stage within the query
    # Features (unit-space θ; raw-space non-decision variables).
    theta_c: np.ndarray            # (S, 8)  unit
    theta_p: np.ndarray            # (S, 9)  unit
    theta_s: np.ndarray            # (S, 2)  unit
    alpha_cbo: np.ndarray          # (S, a)  compile-time input stats
    alpha_true: np.ndarray         # (S, a)  runtime input stats
    beta: np.ndarray               # (S, 3)  partition-size distribution
    gamma: np.ndarray              # (S, g)  contention stats
    # Targets.
    y_subq: np.ndarray             # (S, 2)  [analytical latency, IO GB]
    # Query-level samples (one per query × config).
    q_query_idx: np.ndarray        # (Sq,)
    q_theta_c: np.ndarray          # (Sq, 8)
    q_theta_p: np.ndarray          # (Sq, 9)
    q_theta_s: np.ndarray          # (Sq, 2)
    q_alpha: np.ndarray            # (Sq, a)
    y_query: np.ndarray            # (Sq, 2) [actual latency, IO GB]

    def split(self, fractions=(0.8, 0.1, 0.1), seed: int = 0):
        """Split by *query* (not row) into train/val/test index masks."""
        nq = len(self.queries)
        rng = np.random.default_rng(seed)
        perm = rng.permutation(nq)
        n_tr = int(fractions[0] * nq)
        n_va = int(fractions[1] * nq)
        groups = {"train": perm[:n_tr], "val": perm[n_tr:n_tr + n_va],
                  "test": perm[n_tr + n_va:]}
        masks = {}
        for name, qids in groups.items():
            qset = set(qids.tolist())
            masks[name] = (
                np.array([qi in qset for qi in self.query_idx]),
                np.array([qi in qset for qi in self.q_query_idx]),
            )
        return masks


def _alpha_stats(rows: Sequence[float], bys: Sequence[float]) -> np.ndarray:
    """Input-characteristics vector: log-scaled sizes of the stage inputs."""
    r = float(sum(rows))
    b = float(sum(bys))
    r1 = float(max(rows))
    b1 = float(max(bys))
    return np.array([np.log1p(r) / 20.0, np.log1p(b) / 25.0,
                     np.log1p(r1) / 20.0, np.log1p(b1) / 25.0,
                     len(rows) / 2.0], np.float64)


ALPHA_DIM = 5
GAMMA_DIM = 4


def collect_traces(
    queries: Sequence[Query],
    n_conf_per_query: int,
    *,
    seed: int = 0,
    cost: CostModel = DEFAULT_COST,
) -> TraceSet:
    """Run every query under LHS-sampled configurations; gather all targets."""
    cs, ps, ss = theta_c_space(), theta_p_space(), theta_s_space()
    rng = np.random.default_rng(seed)

    rows: Dict[str, List[np.ndarray]] = {k: [] for k in
        ["qi", "si", "tc", "tp", "ts", "ac", "at", "be", "ga", "y"]}
    qrows: Dict[str, List[np.ndarray]] = {k: [] for k in
        ["qi", "tc", "tp", "ts", "al", "y"]}

    for qi, q in enumerate(queries):
        n = n_conf_per_query
        u_c = cs.sample_lhs(rng, n)
        u_p = ps.sample_lhs(rng, n)
        u_s = ss.sample_lhs(rng, n)
        tc = cs.to_raw(u_c)
        tp = ps.to_raw(u_p)
        ts = ss.to_raw(u_s)
        sim = simulate_query(q, tc, tp, ts, cost=cost, runtime_reopt=True,
                             rng=np.random.default_rng(seed + qi))

        depths = q.subq_depths()
        # Contention γ per stage: tasks of sibling stages at the same depth.
        for sq in q.subqs:
            d = depths[sq.sq_id]
            sib = [j for j in range(q.n_subqs)
                   if depths[j] == d and j != sq.sq_id]
            p = sim.per_subq[sq.sq_id]
            sib_tasks = (np.sum([sim.per_subq[j].n_tasks for j in sib], 0)
                         if sib else np.zeros(n))
            sib_work = (np.sum([sim.per_subq[j].task_seconds for j in sib], 0)
                        if sib else np.zeros(n))
            gamma = contention_gamma(sib_tasks, sib_work, len(sib), d)

            rows["qi"].append(np.full(n, qi))
            rows["si"].append(np.full(n, sq.sq_id))
            rows["tc"].append(u_c)
            rows["tp"].append(u_p)
            rows["ts"].append(u_s)
            rows["ac"].append(np.tile(_alpha_stats(
                sq.est_input_rows, sq.est_input_bytes), (n, 1)))
            rows["at"].append(np.tile(_alpha_stats(
                sq.input_rows, sq.input_bytes), (n, 1)))
            rows["be"].append(p.beta)
            rows["ga"].append(gamma)
            rows["y"].append(np.stack([p.ana_latency, p.io_gb], -1))

        qrows["qi"].append(np.full(n, qi))
        qrows["tc"].append(u_c)
        qrows["tp"].append(u_p)
        qrows["ts"].append(u_s)
        tot_r = sum(s.out_rows for s in q.subqs if s.kind == "scan")
        tot_b = sum(s.out_bytes for s in q.subqs if s.kind == "scan")
        qrows["al"].append(np.tile(
            _alpha_stats([tot_r], [tot_b]), (n, 1)))
        qrows["y"].append(np.stack([sim.actual_latency, sim.io_gb], -1))

    cat = lambda k, d: np.concatenate(d[k], axis=0)
    return TraceSet(
        queries=list(queries),
        query_idx=cat("qi", rows).astype(int),
        subq_idx=cat("si", rows).astype(int),
        theta_c=cat("tc", rows), theta_p=cat("tp", rows),
        theta_s=cat("ts", rows),
        alpha_cbo=cat("ac", rows), alpha_true=cat("at", rows),
        beta=cat("be", rows), gamma=cat("ga", rows),
        y_subq=cat("y", rows),
        q_query_idx=cat("qi", qrows).astype(int),
        q_theta_c=cat("tc", qrows), q_theta_p=cat("tp", qrows),
        q_theta_s=cat("ts", qrows), q_alpha=cat("al", qrows),
        y_query=cat("y", qrows),
    )
