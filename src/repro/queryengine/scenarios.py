"""Nonstationary serving scenarios: seeded event timelines over streams.

The paper's headline claim is *adaptability* — the optimizer dominates
alternatives "when shifting preferences between latency and cost" (§6) —
but a stationary Poisson stream with fixed tenants and fixed capacity
never exercises it.  A :class:`ScenarioSpec` composes the three
nonstationary axes the serving stack must adapt to:

* **arrival shape** — any :class:`~repro.queryengine.workloads.ArrivalModel`
  per tenant, including the time-varying kinds (``diurnal`` sinusoid,
  ``spike`` flash crowd, ``ramp``);
* **event timeline** — a seeded list of :class:`ScenarioEvent`\\ s:
  mid-stream tenant preference-weight shifts (``weights``), tenant churn
  (``join`` / ``leave``), and server capacity changes (``capacity``);
* **tenant population** — the usual
  :class:`~repro.queryengine.workloads.TenantSpec` mix (SLO classes,
  shares, priorities, rate limits).

Determinism contract: :meth:`ScenarioSpec.build` is a **pure function of
its seeds**.  Weight shifts are resolved at build time — every
:class:`~repro.queryengine.workloads.StreamRequest` is stamped with the
weights effective at its arrival — so the (request → weights) mapping
never depends on when the server happens to dequeue a request, and the
streamed server's surviving outputs replay bit-identically offline even
across shift and churn boundaries (``tests/test_scenarios.py`` pins this
for the whole :func:`scenario_matrix`).

Capacity events are *not* folded into the requests (they are server-side,
not client-side); :meth:`ScenarioSpec.build` returns them alongside the
stream and ``OptimizerServer.serve(requests, capacity_events=...)``
consumes them on its simulated clock.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, NamedTuple, Optional, Sequence, Tuple

from .workloads import (ArrivalModel, StreamRequest, TenantSpec,
                        _tenant_seed, serving_stream)

__all__ = ["ScenarioEvent", "CapacityEvent", "Scenario", "ScenarioSpec",
           "scenario_matrix", "EVENT_KINDS"]

EVENT_KINDS = ("weights", "join", "leave", "capacity")


@dataclasses.dataclass(frozen=True)
class ScenarioEvent:
    """One timeline event; the payload fields required depend on ``kind``.

    * ``weights``  — tenant ``tenant`` switches preference weights to
      ``weights`` for every request arriving at or after ``at_s``;
    * ``join``     — a new tenant (``spec``) starts emitting at ``at_s``;
    * ``leave``    — tenant ``tenant`` stops emitting at ``at_s`` (its
      requests arriving at or after ``at_s`` are dropped at build time);
    * ``capacity`` — the server's base ``max_batch`` becomes ``max_batch``
      at simulated time ``at_s``.
    """
    at_s: float
    kind: str
    tenant: Optional[str] = None
    weights: Optional[Tuple[float, float]] = None
    spec: Optional[TenantSpec] = None
    max_batch: Optional[int] = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; expected "
                             f"one of {EVENT_KINDS}")
        if not (math.isfinite(self.at_s) and self.at_s >= 0.0):
            raise ValueError(f"at_s must be finite and >= 0, got {self.at_s}")
        if self.kind == "weights":
            if self.tenant is None or self.weights is None:
                raise ValueError("weights event needs tenant= and weights=")
            if len(self.weights) != 2:
                raise ValueError(f"weights must be a (latency, cost) pair, "
                                 f"got {self.weights}")
        elif self.kind == "join":
            if self.spec is None:
                raise ValueError("join event needs spec=")
            if self.tenant is not None and self.tenant != self.spec.name:
                raise ValueError(f"join tenant {self.tenant!r} != spec name "
                                 f"{self.spec.name!r}")
        elif self.kind == "leave":
            if self.tenant is None:
                raise ValueError("leave event needs tenant=")
        elif self.kind == "capacity":
            if self.max_batch is None or self.max_batch < 1:
                raise ValueError("capacity event needs max_batch= >= 1, got "
                                 f"{self.max_batch}")


class CapacityEvent(NamedTuple):
    """Server capacity change on the simulated clock."""
    at_s: float
    max_batch: int


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A built scenario: the timed stream plus the server-side inputs."""
    spec: "ScenarioSpec"
    requests: Tuple[StreamRequest, ...]
    capacity_events: Tuple[CapacityEvent, ...]
    tenants: Tuple[TenantSpec, ...]   # initial + joined, declaration order


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Declarative nonstationary scenario; :meth:`build` is seed-pure."""
    name: str
    benchmark: str = "tpch"
    tenants: Tuple[TenantSpec, ...] = ()
    n_per_tenant: int = 8
    events: Tuple[ScenarioEvent, ...] = ()
    zipf_a: float = 1.3
    n_variants: int = 3

    def __post_init__(self):
        if not self.name:
            raise ValueError("scenario name must be non-empty")
        if not self.tenants:
            raise ValueError("scenario needs at least one tenant")
        if self.n_per_tenant < 1:
            raise ValueError(f"n_per_tenant must be >= 1, got "
                             f"{self.n_per_tenant}")
        names = [t.name for t in self.tenants] \
            + [e.spec.name for e in self.events if e.kind == "join"]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in scenario: {names}")
        known = set(names)
        for e in self.events:
            if e.kind in ("weights", "leave") and e.tenant not in known:
                raise ValueError(f"{e.kind} event names unknown tenant "
                                 f"{e.tenant!r}")

    # -- per-tenant timeline collation --------------------------------------
    def _shifts(self, name: str) -> List[ScenarioEvent]:
        return sorted((e for e in self.events
                       if e.kind == "weights" and e.tenant == name),
                      key=lambda e: e.at_s)

    def _leave_at(self, name: str) -> float:
        return min((e.at_s for e in self.events
                    if e.kind == "leave" and e.tenant == name),
                   default=math.inf)

    def build(self, *, seed: int = 0, query_seed: int = 0) -> Scenario:
        """Materialize the scenario: a merged, weight-stamped request
        stream (sorted by arrival, globally re-rid'd) plus the capacity
        timeline and the full tenant population for server registration.
        """
        pop: List[Tuple[TenantSpec, Optional[float]]] = \
            [(t, None) for t in self.tenants]
        pop += [(e.spec, e.at_s) for e in sorted(
            (e for e in self.events if e.kind == "join"),
            key=lambda e: (e.at_s, e.spec.name))]
        merged: List[StreamRequest] = []
        for spec, join_at in pop:
            arrivals = spec.arrivals if join_at is None else \
                dataclasses.replace(spec.arrivals, start_s=join_at)
            reqs = serving_stream(
                self.benchmark, self.n_per_tenant,
                seed=_tenant_seed(seed, spec.name), zipf_a=self.zipf_a,
                n_variants=self.n_variants, arrivals=arrivals,
                query_seed=query_seed)
            leave_at = self._leave_at(spec.name)
            shifts = self._shifts(spec.name)
            for r in reqs:
                if r.arrival_s >= leave_at:
                    continue
                w = spec.weights
                for ev in shifts:
                    if ev.at_s <= r.arrival_s:
                        w = ev.weights
                merged.append(dataclasses.replace(
                    r, tenant=spec.name, weights=w))
        merged.sort(key=lambda r: (r.arrival_s, r.tenant, r.rid))
        cap = tuple(sorted(
            (CapacityEvent(e.at_s, int(e.max_batch))
             for e in self.events if e.kind == "capacity"),
            key=lambda c: c.at_s))
        return Scenario(
            spec=self,
            requests=tuple(dataclasses.replace(r, rid=i)
                           for i, r in enumerate(merged)),
            capacity_events=cap,
            tenants=tuple(s for s, _ in pop))


# ---------------------------------------------------------------------------
# The bench/test matrix: arrival shapes × event timelines
# ---------------------------------------------------------------------------

def _shape_arrivals(shape: str, rate_qps: float, horizon_s: float
                    ) -> ArrivalModel:
    if shape == "diurnal":
        return ArrivalModel(kind="diurnal", rate_qps=rate_qps,
                            period_s=horizon_s, amplitude=0.8)
    if shape == "flash_crowd":
        return ArrivalModel(kind="spike", rate_qps=rate_qps,
                            spike_at_s=0.25 * horizon_s,
                            spike_dur_s=0.25 * horizon_s, spike_factor=4.0)
    if shape == "ramp":
        return ArrivalModel(kind="ramp", rate_qps=rate_qps,
                            ramp_to_qps=3.0 * rate_qps,
                            ramp_dur_s=0.5 * horizon_s)
    raise ValueError(f"unknown arrival shape {shape!r}")


ARRIVAL_SHAPES = ("diurnal", "flash_crowd", "ramp")
TIMELINES = ("steady", "pref_shift", "churn")


def scenario_matrix(*, benchmark: str = "tpch", n_per_tenant: int = 5,
                    rate_qps: float = 30.0) -> List[ScenarioSpec]:
    """The full (arrival shape × event timeline) scenario matrix.

    Each scenario carries three tenants spanning the SLO classes — a
    ``strict`` latency-weighted tenant with priority, a ``degrade``
    balanced tenant, and a rate-limited ``best_effort`` cost-weighted
    tenant.  ``pref_shift`` timelines flip two tenants' latency↔cost
    preferences mid-stream; ``churn`` timelines add a joining tenant, a
    leaving tenant, and a capacity dip-and-recover.  Event times scale
    with the expected stream horizon ``n_per_tenant / rate_qps`` so the
    matrix stays meaningful at any configured load.
    """
    horizon_s = n_per_tenant / rate_qps
    out: List[ScenarioSpec] = []
    for shape in ARRIVAL_SHAPES:
        arr = _shape_arrivals(shape, rate_qps, horizon_s)
        tenants = (
            TenantSpec(name="strict", weights=(0.9, 0.1), slo="strict",
                       priority=1, arrivals=arr),
            TenantSpec(name="deg", weights=(0.5, 0.5), slo="degrade",
                       arrivals=arr),
            TenantSpec(name="be", weights=(0.1, 0.9), slo="best_effort",
                       rate_limit_qps=2.0 * rate_qps, rate_limit_burst=4.0,
                       arrivals=arr),
        )
        for timeline in TIMELINES:
            if timeline == "steady":
                events: Tuple[ScenarioEvent, ...] = ()
            elif timeline == "pref_shift":
                events = (
                    ScenarioEvent(at_s=0.5 * horizon_s, kind="weights",
                                  tenant="strict", weights=(0.1, 0.9)),
                    ScenarioEvent(at_s=0.6 * horizon_s, kind="weights",
                                  tenant="be", weights=(0.9, 0.1)),
                )
            else:  # churn
                events = (
                    ScenarioEvent(at_s=0.4 * horizon_s, kind="join",
                                  spec=TenantSpec(
                                      name="joiner", weights=(0.7, 0.3),
                                      arrivals=dataclasses.replace(
                                          arr, kind="poisson"))),
                    ScenarioEvent(at_s=0.6 * horizon_s, kind="leave",
                                  tenant="be"),
                    ScenarioEvent(at_s=0.3 * horizon_s, kind="capacity",
                                  max_batch=2),
                    ScenarioEvent(at_s=0.7 * horizon_s, kind="capacity",
                                  max_batch=8),
                )
            out.append(ScenarioSpec(
                name=f"{shape}-{timeline}", benchmark=benchmark,
                tenants=tenants, n_per_tenant=n_per_tenant, events=events))
    return out
