"""Adaptive Query Execution loop with runtime parameter optimization.

Reproduces the paper's runtime side (§5.2): stages execute in topological
order; each stage completion collapses the logical plan (L̄QP) and exposes
*true* statistics; the runtime optimizer is invoked — unless pruned — to
re-tune θp for the collapsed plan and θs for each newly created query stage.
Spark holds a single live copy of θp/θs, so fine-grained control emerges from
*when* each stage is planned: a stage's effective θp is the copy in effect at
its planning event.

Join-algorithm convertibility is enforced: AQE can upgrade SMJ→SHJ→BHJ from
runtime statistics but can never demote a planned broadcast — the submission
copy therefore carries risk that runtime tuning cannot undo (paper Fig. 3(b)).

Request pruning (§5.2, App. C.2): (1) LQP re-optimization requests are sent
only when the completed stage clears the *last* dependency of some join —
non-join events and joins with incomplete input statistics are skipped or
deferred; (2) joins whose decision is statistically obvious (build side far
from every θp threshold) are skipped; (3) QS requests are sent only for
non-scan stages whose shuffle input exceeds the advisory partition size s1.
The paper reports 86%/92% fewer requests on TPC-H/TPC-DS.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .plan import Query, SubQ
from .simulator import (CostModel, DEFAULT_COST, QuerySim, decide_join,
                        plan_joins, simulate_query, upgrade_joins)

__all__ = ["AQEResult", "AQEPlanState", "LQPRequest", "QSRequest",
           "aqe_request_stream", "realize_aqe", "run_with_aqe",
           "RuntimeOptimizer"]


# A runtime optimizer callback: (query, collapsed_ids, theta_c, theta_p_cur,
# true-stats dict) -> new theta_p row (9,) or None to keep current.
RuntimeOptimizer = Callable[..., Optional[np.ndarray]]


@dataclasses.dataclass
class LQPRequest:
    """L̄QP re-optimization request: re-tune θp before planning ``subq``."""
    query: Query
    subq: SubQ
    theta_c: np.ndarray          # (8,) fixed context
    theta_p: np.ndarray          # (9,) θp copy in effect at the event
    kind: str = "lqp"


@dataclasses.dataclass
class QSRequest:
    """QS optimization request: re-tune θs for the newly created ``subq``."""
    query: Query
    subq: SubQ
    theta_c: np.ndarray
    theta_s: np.ndarray          # (2,) θs copy in effect at the event
    kind: str = "qs"


@dataclasses.dataclass
class AQEPlanState:
    """Planning outcome of one AQE pass, before execution is realized."""
    theta_p_eff: np.ndarray      # (m, 9) θp in effect per stage
    theta_s_eff: np.ndarray      # (m, 2)
    planned: np.ndarray          # (m,) submission-time join algorithms
    lqp_requests_sent: int
    qs_requests_sent: int
    requests_total: int


@dataclasses.dataclass
class AQEResult:
    sim: QuerySim                      # realized execution (n = 1)
    theta_p_eff: np.ndarray            # (m, 9) θp in effect per stage
    theta_s_eff: np.ndarray            # (m, 2)
    final_join: np.ndarray             # (m,) realized algorithms
    lqp_requests_sent: int
    qs_requests_sent: int
    requests_total: int                # unpruned request count (~2m)

    @property
    def requests_sent(self) -> int:
        return self.lqp_requests_sent + self.qs_requests_sent

    @property
    def prune_rate(self) -> float:
        if self.requests_total == 0:
            return 0.0
        return 1.0 - self.requests_sent / self.requests_total


def _join_obvious(sq: SubQ, theta_p: np.ndarray, margin: float = 4.0) -> bool:
    """True when runtime statistics cannot change the join decision.

    The build side is more than ``margin``× away from both the broadcast
    (s4) and shuffled-hash (s3) thresholds, on the same side as the estimate
    — re-optimizing cannot flip the parametric rule.
    """
    build_true = min(sq.input_bytes)
    build_est = min(sq.est_input_bytes)
    for thr_mb in (theta_p[2], theta_p[3]):
        thr = thr_mb * 1e6
        if thr <= 0:
            continue
        same_side = (build_true > thr) == (build_est > thr)
        near = thr / margin <= build_true <= thr * margin
        if near or not same_side:
            return False
    return True


def aqe_request_stream(
    query: Query,
    theta_c: np.ndarray,
    theta_p0: np.ndarray,
    theta_s0: np.ndarray,
    *,
    prune: bool = True,
):
    """Generator form of the AQE planning loop (the batchable protocol).

    Walks stage completions in topological order and *yields* each unpruned
    :class:`LQPRequest` / :class:`QSRequest` instead of invoking a callback;
    the consumer answers via ``send(new_theta_row)`` (or ``send(None)`` to
    keep the current copy).  Returns the final :class:`AQEPlanState` as the
    generator's ``StopIteration.value``.

    :func:`run_with_aqe` drives this with synchronous callbacks; the serving
    layer (``repro.serve.runtime``) drives many streams concurrently and
    fuses their outstanding requests into batched optimizer calls.  Both see
    the identical event order, pruning decisions, and request counts.
    """
    theta_c = np.asarray(theta_c, np.float64).reshape(-1)
    theta_p0 = np.asarray(theta_p0, np.float64).reshape(-1)
    theta_s0 = np.asarray(theta_s0, np.float64).reshape(-1)
    m = query.n_subqs
    topo = query.topo_subqs()

    theta_p_eff = np.tile(theta_p0, (m, 1))
    theta_s_eff = np.tile(theta_s0, (m, 1))

    # Submission-time planned algorithms (CBO estimates + θp0): the physical
    # plan Spark builds before any stage runs.
    planned = plan_joins(query, theta_p_eff[None, :, :],
                         from_estimates=True)[0]

    completed: set = set()
    theta_p_cur = theta_p0.copy()
    lqp_sent = 0
    qs_sent = 0
    # Unpruned baseline: every stage completion triggers one L̄QP request and
    # every created stage triggers one QS request.
    requests_total = 2 * m

    # Map each join to the event (child completion) that clears its inputs.
    for sid in topo:
        sq = query.subqs[sid]

        # --- L̄QP re-optimization opportunity before planning this stage ---
        if sq.kind == "join":
            stats_ready = all(c in completed for c in sq.children)
            send = stats_ready
            if prune and send:
                send = not _join_obvious(sq, theta_p_cur)
            if send:
                newp = yield LQPRequest(query=query, subq=sq,
                                        theta_c=theta_c,
                                        theta_p=theta_p_cur)
                lqp_sent += 1
                if newp is not None:
                    theta_p_cur = np.asarray(newp, np.float64).reshape(-1)
        theta_p_eff[sid] = theta_p_cur

        # --- QS optimization when the stage is created ---------------------
        send_qs = True
        if prune:
            shuffle_in = sum(sq.input_bytes)
            s1_bytes = max(theta_p_cur[0], 1.0) * 1e6
            send_qs = (sq.kind != "scan") and (shuffle_in >= s1_bytes)
        if send_qs:
            qs_sent += 1
            news = yield QSRequest(query=query, subq=sq, theta_c=theta_c,
                                   theta_s=theta_s_eff[sid])
            if news is not None:
                theta_s_eff[sid] = np.asarray(news, np.float64).reshape(-1)

        completed.add(sid)

    return AQEPlanState(theta_p_eff=theta_p_eff, theta_s_eff=theta_s_eff,
                        planned=planned, lqp_requests_sent=lqp_sent,
                        qs_requests_sent=qs_sent,
                        requests_total=requests_total)


def realize_aqe(
    query: Query,
    theta_c: np.ndarray,
    state: AQEPlanState,
    *,
    cost: CostModel = DEFAULT_COST,
    rng: Optional[np.random.Generator] = None,
) -> AQEResult:
    """Realize execution for a finished planning pass.

    Runtime decisions come from true statistics under each stage's effective
    θp, constrained by submission-planned convertibility (a planned broadcast
    is never demoted).
    """
    theta_c = np.asarray(theta_c, np.float64).reshape(-1)
    runtime_choice = plan_joins(query, state.theta_p_eff[None, :, :],
                                from_estimates=False)[0]
    final_join = upgrade_joins(state.planned, runtime_choice)
    sim = simulate_query(
        query, theta_c[None, :], state.theta_p_eff[None, :, :],
        state.theta_s_eff[None, :, :], cost=cost, aqe=True,
        planned_join=final_join[None, :], rng=rng)
    return AQEResult(sim=sim, theta_p_eff=state.theta_p_eff,
                     theta_s_eff=state.theta_s_eff, final_join=final_join,
                     lqp_requests_sent=state.lqp_requests_sent,
                     qs_requests_sent=state.qs_requests_sent,
                     requests_total=state.requests_total)


def run_with_aqe(
    query: Query,
    theta_c: np.ndarray,
    theta_p0: np.ndarray,
    theta_s0: np.ndarray,
    *,
    lqp_optimizer: Optional[RuntimeOptimizer] = None,
    qs_optimizer: Optional[RuntimeOptimizer] = None,
    prune: bool = True,
    cost: CostModel = DEFAULT_COST,
    rng: Optional[np.random.Generator] = None,
) -> AQEResult:
    """Execute one query under AQE with optional runtime re-optimization.

    Synchronous driver over :func:`aqe_request_stream`: each yielded request
    is answered immediately by the matching callback.

    Args:
      theta_c: (8,) context parameters (fixed for the whole query).
      theta_p0: (9,) submission-time θp copy (paper §5.2 aggregation output).
      theta_s0: (2,) submission-time θs copy.
      lqp_optimizer / qs_optimizer: runtime tuning callbacks; None reproduces
        plain Spark AQE under the submitted configuration.
      prune: apply the request-pruning rules.
    """
    stream = aqe_request_stream(query, theta_c, theta_p0, theta_s0,
                                prune=prune)
    response: Optional[np.ndarray] = None
    while True:
        try:
            req = stream.send(response)
        except StopIteration as stop:
            state: AQEPlanState = stop.value
            break
        if req.kind == "lqp":
            response = None if lqp_optimizer is None else lqp_optimizer(
                query=req.query, subq=req.subq, theta_c=req.theta_c,
                theta_p=req.theta_p)
        else:
            response = None if qs_optimizer is None else qs_optimizer(
                query=req.query, subq=req.subq, theta_c=req.theta_c,
                theta_s=req.theta_s)
    return realize_aqe(query, theta_c, state, cost=cost, rng=rng)
