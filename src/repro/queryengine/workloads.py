"""TPC-H-like and TPC-DS-like workload generators.

The paper evaluates on 22 TPC-H and 102 TPC-DS queries at scale factor 100,
plus 50k parametric variants per benchmark used as model-training templates.
Running a real Spark cluster is out of scope here, so this module generates
*structurally faithful* workloads: star/snowflake join DAGs over catalogs
whose table cardinalities match SF-100 TPC-H / TPC-DS, with per-template
deterministic shapes and per-variant parametric perturbations (selectivities,
join fan-outs) — the same role the benchmark plays in the paper: a family of
operator DAGs with heavy-tailed sizes and compounding cardinality-estimation
error.

Template sizes are drawn to match the paper's reported extremes: TPC-H up to
12 subQs (Q9), TPC-DS up to 47 subQs.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .plan import Operator, Query, SubQ, cbo_estimate

__all__ = [
    "Table", "TPCH_TABLES", "TPCDS_TABLES",
    "make_query", "make_benchmark", "parametric_variants", "default_workload",
    "serving_stream", "ArrivalModel", "StreamRequest", "TenantSpec",
    "multi_tenant_stream", "SLO_CLASSES",
]


@dataclasses.dataclass(frozen=True)
class Table:
    name: str
    rows: float
    width: float  # bytes/row

    @property
    def bytes(self) -> float:
        return self.rows * self.width


# Scale factor 100 catalogs (rows; widths approximate on-disk widths).
TPCH_TABLES: Dict[str, Table] = {
    t.name: t for t in [
        Table("lineitem", 600e6, 120),
        Table("orders", 150e6, 100),
        Table("partsupp", 80e6, 140),
        Table("part", 20e6, 150),
        Table("customer", 15e6, 180),
        Table("supplier", 1e6, 160),
        Table("nation", 25, 120),
        Table("region", 5, 120),
    ]
}

TPCDS_TABLES: Dict[str, Table] = {
    t.name: t for t in [
        Table("store_sales", 288e6, 164),
        Table("catalog_sales", 144e6, 226),
        Table("web_sales", 72e6, 226),
        Table("inventory", 399e6, 16),
        Table("store_returns", 28.8e6, 134),
        Table("catalog_returns", 14.4e6, 166),
        Table("web_returns", 7.2e6, 162),
        Table("customer", 2e6, 132),
        Table("customer_address", 1e6, 110),
        Table("customer_demographics", 1.92e6, 42),
        Table("item", 204e3, 281),
        Table("date_dim", 73049, 141),
        Table("time_dim", 86400, 59),
        Table("store", 402, 263),
        Table("warehouse", 15, 117),
        Table("web_site", 24, 292),
        Table("web_page", 2040, 96),
        Table("promotion", 1000, 124),
        Table("household_demographics", 7200, 21),
        Table("income_band", 20, 16),
        Table("reason", 55, 38),
        Table("ship_mode", 20, 56),
        Table("call_center", 30, 305),
        Table("catalog_page", 20400, 139),
    ]
}

_FACTS = {
    "tpch": ["lineitem", "orders", "partsupp"],
    "tpcds": ["store_sales", "catalog_sales", "web_sales", "inventory",
              "store_returns", "catalog_returns", "web_returns"],
}

_PRED_VOCAB = [
    "l_shipdate", "l_quantity", "o_orderdate", "p_type", "c_mktsegment",
    "ss_sold_date", "d_year", "i_category", "ca_state", "between", "in",
    "like", "ge", "le", "eq", "and", "or", "sum", "avg", "count", "group",
]


# ---------------------------------------------------------------------------
# Template structure
# ---------------------------------------------------------------------------

def _template_tables(benchmark: str, template: int,
                     rng: np.random.Generator) -> List[str]:
    cat = TPCH_TABLES if benchmark == "tpch" else TPCDS_TABLES
    facts = _FACTS[benchmark]
    dims = [n for n in cat if n not in facts]
    if benchmark == "tpch":
        # 22 templates spanning 1..6 tables (Q1-style single-table scans up
        # to Q8/Q9-style 6-table joins).
        n_tables = int(rng.integers(1, 7))
    else:
        # 102 templates; heavy tail up to 24 tables -> ~47 subQs.
        n_tables = int(np.clip(rng.geometric(0.18) + 2, 3, 24))
    n_facts = min(1 + int(rng.random() < 0.3) + int(rng.random() < 0.15),
                  n_tables, len(facts))
    chosen = list(rng.choice(facts, size=n_facts, replace=False))
    n_dims = n_tables - n_facts
    if n_dims > 0:
        # Dims can repeat across branches in DS (date_dim joined many times);
        # sample with replacement beyond the distinct pool.
        replace = n_dims > len(dims)
        chosen += list(rng.choice(dims, size=n_dims, replace=replace))
    return chosen


def make_query(benchmark: str, template: int, *, variant: int = 0,
               seed: int = 0) -> Query:
    """Build one query (template + parametric variant) with true + CBO cards.

    The template's *structure* (tables, join tree shape) depends only on
    ``(benchmark, template)``; the variant perturbs selectivities/fan-outs
    — mirroring the paper's 50k parametric queries per benchmark.
    """
    cat = TPCH_TABLES if benchmark == "tpch" else TPCDS_TABLES
    srng = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(benchmark.encode()) & 0xFFFF, template]))
    tables = _template_tables(benchmark, template, srng)
    # Variant rng: perturbs the numeric knobs only.
    vrng = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(benchmark.encode()) & 0xFFFF, template,
                                1000 + variant]))
    # CBO error rng: deterministic per (template, variant) so the compile-time
    # optimizer is *consistently* wrong, as a real CBO is.
    erng = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(benchmark.encode()) & 0xFFFF, template,
                                7777 + variant]))

    ops: List[Operator] = []
    subqs: List[SubQ] = []

    def new_op(op_type: str, children: List[int], rows: float, bys: float,
               est_rows: float, est_bytes: float,
               toks: Tuple[str, ...] = ()) -> int:
        op = Operator(len(ops), op_type, children, rows, bys,
                      est_rows, est_bytes, toks)
        ops.append(op)
        return op.op_id

    def pred(k: int = 3) -> Tuple[str, ...]:
        return tuple(srng.choice(_PRED_VOCAB, size=k))

    # ---- scan subQs --------------------------------------------------------
    # Each scan: scan -> filter -> project; selectivity & projection fraction
    # vary by variant.
    frontier: List[Tuple[int, float, float, float, float, float]] = []
    # (sq_id, rows, bytes, est_rows, est_bytes, width)
    for t_name in tables:
        tab = cat[t_name]
        sel_base = float(np.exp(srng.uniform(np.log(2e-3), np.log(0.6))))
        sel = float(np.clip(sel_base * np.exp(vrng.normal(0, 0.5)), 1e-5, 1.0))
        proj = float(srng.uniform(0.25, 0.9))
        rows = max(1.0, tab.rows * sel)
        width = tab.width * proj
        bys = rows * width
        est_rows = cbo_estimate(rows, 0, erng)
        est_bytes = est_rows * width
        o_scan = new_op("scan", [], tab.rows, tab.bytes, tab.rows, tab.bytes,
                        (t_name,))
        o_fil = new_op("filter", [o_scan], rows, rows * tab.width,
                       est_rows, est_rows * tab.width, pred())
        o_prj = new_op("project", [o_fil], rows, bys, est_rows, est_bytes,
                       pred(2))
        sq = SubQ(
            sq_id=len(subqs), op_ids=[o_scan, o_fil, o_prj], children=[],
            kind="scan", root_op=o_prj, table=t_name,
            input_rows=(tab.rows,), input_bytes=(tab.bytes,),
            est_input_rows=(tab.rows,), est_input_bytes=(tab.bytes,),
            out_rows=rows, out_bytes=bys, est_out_rows=est_rows,
            est_out_bytes=est_bytes,
            cpu_weight=float(srng.uniform(0.6, 1.2)),
            skew=float(srng.beta(1.2, 4.0)), depth=0,
        )
        subqs.append(sq)
        frontier.append((sq.sq_id, rows, bys, est_rows, est_bytes, width))

    # ---- join subQs (left-deep with occasional bushy merges) --------------
    srng2 = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(benchmark.encode()) & 0xFFFF, template, 5]))
    depth = 0
    while len(frontier) > 1:
        depth += 1
        # Bias toward joining the largest with a small one (star schema).
        frontier.sort(key=lambda f: -f[1])
        i = 0
        j = int(srng2.integers(1, len(frontier)))
        (sq_l, r_l, b_l, er_l, eb_l, w_l) = frontier.pop(max(i, j))
        (sq_r, r_r, b_r, er_r, eb_r, w_r) = frontier.pop(min(i, j))
        fan_base = float(np.exp(srng2.uniform(np.log(0.05), np.log(2.5))))
        fan = float(np.clip(fan_base * np.exp(vrng.normal(0, 0.4)), 1e-4, 8.0))
        rows = max(1.0, fan * max(r_l, r_r))
        width = (w_l + w_r) * float(srng2.uniform(0.4, 0.8))
        bys = rows * width
        est_rows = cbo_estimate(rows, depth, erng)
        est_bytes = est_rows * width
        left_root = subqs[sq_l].root_op
        right_root = subqs[sq_r].root_op
        o_join = new_op("join", [left_root, right_root], rows, bys,
                        est_rows, est_bytes, pred())
        members = [o_join]
        root = o_join
        if srng2.random() < 0.5:
            root = new_op("project", [o_join], rows, bys * 0.9,
                          est_rows, est_bytes * 0.9, pred(2))
            members.append(root)
            bys *= 0.9
            est_bytes *= 0.9
        sq = SubQ(
            sq_id=len(subqs), op_ids=members, children=[sq_l, sq_r],
            kind="join", root_op=root,
            input_rows=(r_l, r_r), input_bytes=(b_l, b_r),
            est_input_rows=(er_l, er_r), est_input_bytes=(eb_l, eb_r),
            out_rows=rows, out_bytes=bys, est_out_rows=est_rows,
            est_out_bytes=est_bytes,
            cpu_weight=float(srng2.uniform(1.0, 2.0)),
            skew=float(srng2.beta(1.5, 3.0)), depth=depth,
        )
        subqs.append(sq)
        frontier.append((sq.sq_id, rows, bys, est_rows, est_bytes, width))

    # ---- final aggregate subQ ---------------------------------------------
    (sq_top, r_t, b_t, er_t, eb_t, w_t) = frontier[0]
    red = float(np.exp(srng2.uniform(np.log(1e-4), np.log(0.2))))
    rows = max(1.0, r_t * red)
    bys = rows * w_t * 0.5
    est_rows = cbo_estimate(rows, depth + 1, erng)
    est_bytes = est_rows * w_t * 0.5
    top_root = subqs[sq_top].root_op
    o_agg = new_op("agg", [top_root], rows, bys, est_rows, est_bytes, pred())
    members = [o_agg]
    root = o_agg
    if srng2.random() < 0.5:
        root = new_op("sort", [o_agg], rows, bys, est_rows, est_bytes, pred(1))
        members.append(root)
    sq = SubQ(
        sq_id=len(subqs), op_ids=members, children=[sq_top], kind="agg",
        root_op=root,
        input_rows=(r_t,), input_bytes=(b_t,),
        est_input_rows=(er_t,), est_input_bytes=(eb_t,),
        out_rows=rows, out_bytes=bys, est_out_rows=est_rows,
        est_out_bytes=est_bytes,
        cpu_weight=float(srng2.uniform(1.0, 1.8)),
        skew=float(srng2.beta(1.2, 5.0)), depth=depth + 1,
    )
    subqs.append(sq)

    return Query(qid=f"{benchmark}-t{template:03d}-v{variant}", ops=ops,
                 subqs=subqs, benchmark=benchmark, template=template)


def make_benchmark(benchmark: str, *, seed: int = 0) -> List[Query]:
    """The paper's evaluation workloads: 22 TPC-H / 102 TPC-DS queries."""
    n = 22 if benchmark == "tpch" else 102
    return [make_query(benchmark, t, variant=0, seed=seed) for t in range(n)]


def parametric_variants(benchmark: str, template: int, n: int, *,
                        seed: int = 0, start: int = 1) -> List[Query]:
    """Parametric training queries from one template (paper: 50k per bench)."""
    return [make_query(benchmark, template, variant=v, seed=seed)
            for v in range(start, start + n)]


_STATIONARY_KINDS = ("poisson", "uniform", "fixed")
_NONSTATIONARY_KINDS = ("diurnal", "spike", "ramp")


@dataclasses.dataclass(frozen=True)
class ArrivalModel:
    """Explicit, seeded arrival-time model for a serving stream.

    Inter-arrival gaps are drawn from a named distribution with their own
    seed stream (independent of the template/variant draws), so stream
    *timing* is reproducible and composable: the same query sequence can be
    replayed under different load shapes.

    Stationary kinds (constant ``rate_qps``):
      * ``poisson`` — exponential gaps with mean ``1/rate_qps`` (open-loop
        Poisson arrivals, the standard serving-load model);
      * ``uniform`` — gaps uniform on ``[0, 2/rate_qps]`` (same mean rate,
        bounded burstiness);
      * ``fixed``   — deterministic gaps of exactly ``1/rate_qps``.

    Nonstationary kinds (inhomogeneous Poisson processes drawn by seeded
    thinning against :meth:`rate_at`, so the whole time-varying stream is
    still a pure function of the seed):
      * ``diurnal`` — sinusoidal rate
        ``rate_qps · (1 + amplitude·sin(2π·(t−start_s)/period_s))``:
        the compressed diurnal traffic curve;
      * ``spike``   — flash crowd: ``rate_qps`` outside the window,
        ``rate_qps·spike_factor`` on ``[spike_at_s, spike_at_s+spike_dur_s)``;
      * ``ramp``    — linear rate from ``rate_qps`` to ``ramp_to_qps``
        over ``ramp_dur_s`` starting at ``start_s``, then holding.
    """
    kind: str = "poisson"
    rate_qps: float = 16.0
    start_s: float = 0.0
    # diurnal
    period_s: float = 60.0
    amplitude: float = 0.8
    # spike (flash crowd)
    spike_at_s: float = 2.0
    spike_dur_s: float = 2.0
    spike_factor: float = 4.0
    # ramp
    ramp_to_qps: float = 32.0
    ramp_dur_s: float = 4.0

    def _validate(self) -> None:
        if self.kind not in _STATIONARY_KINDS + _NONSTATIONARY_KINDS:
            raise ValueError(f"unknown arrival kind: {self.kind!r}")
        if self.rate_qps <= 0:
            raise ValueError(f"rate_qps must be positive, got {self.rate_qps}")
        if self.kind == "diurnal":
            if not 0.0 <= self.amplitude < 1.0:
                raise ValueError(
                    f"amplitude must be in [0, 1), got {self.amplitude} "
                    "(>= 1 would make the instantaneous rate nonpositive)")
            if self.period_s <= 0:
                raise ValueError(f"period_s must be positive, got "
                                 f"{self.period_s}")
        if self.kind == "spike" and (self.spike_factor <= 0
                                     or self.spike_dur_s < 0):
            raise ValueError("spike_factor must be positive and spike_dur_s "
                             f"nonnegative, got {self.spike_factor}, "
                             f"{self.spike_dur_s}")
        if self.kind == "ramp" and (self.ramp_to_qps <= 0
                                    or self.ramp_dur_s <= 0):
            raise ValueError("ramp_to_qps and ramp_dur_s must be positive, "
                             f"got {self.ramp_to_qps}, {self.ramp_dur_s}")

    def rate_at(self, t: float) -> float:
        """Instantaneous arrival rate (qps) at simulated time ``t``."""
        self._validate()
        if self.kind == "diurnal":
            phase = 2.0 * np.pi * (t - self.start_s) / self.period_s
            return self.rate_qps * (1.0 + self.amplitude * np.sin(phase))
        if self.kind == "spike":
            hot = self.spike_at_s <= t < self.spike_at_s + self.spike_dur_s
            return self.rate_qps * (self.spike_factor if hot else 1.0)
        if self.kind == "ramp":
            frac = np.clip((t - self.start_s) / self.ramp_dur_s, 0.0, 1.0)
            return float(self.rate_qps
                         + (self.ramp_to_qps - self.rate_qps) * frac)
        return self.rate_qps

    def _max_rate(self) -> float:
        if self.kind == "diurnal":
            return self.rate_qps * (1.0 + self.amplitude)
        if self.kind == "spike":
            return self.rate_qps * max(self.spike_factor, 1.0)
        if self.kind == "ramp":
            return max(self.rate_qps, self.ramp_to_qps)
        return self.rate_qps

    def draw(self, n: int, seed: int = 0) -> np.ndarray:
        """(n,) nondecreasing arrival times, deterministic per seed."""
        self._validate()
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0xA221]))
        mean_gap = 1.0 / self.rate_qps
        if self.kind == "poisson":
            gaps = rng.exponential(mean_gap, size=n)
        elif self.kind == "uniform":
            gaps = rng.uniform(0.0, 2.0 * mean_gap, size=n)
        elif self.kind == "fixed":
            gaps = np.full(n, mean_gap)
        else:
            # Inhomogeneous Poisson via thinning: candidate arrivals at the
            # envelope rate, each accepted with probability
            # rate_at(t)/rate_max — exact, and a pure function of the seed.
            rmax = self._max_rate()
            out = np.empty(n, np.float64)
            got = 0
            t = self.start_s
            while got < n:
                t += rng.exponential(1.0 / rmax)
                if rng.random() * rmax < self.rate_at(t):
                    out[got] = t
                    got += 1
            return out
        return self.start_s + np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class StreamRequest:
    """One timed tuning request of a serving stream.

    ``weights`` carries the tenant's preference weights *effective at this
    request's arrival time*.  Stationary streams leave it ``None`` (the
    server falls back to the tenant's registered weights); scenario streams
    with mid-stream preference shifts stamp it per request at build time,
    so the (request → weights) mapping is a pure function of the scenario
    seed and replay-equivalence holds exactly across shift boundaries.
    """
    rid: int                 # position in the stream (stable request id)
    query: Query
    arrival_s: float         # simulated-clock arrival time
    tenant: str = "default"  # issuing tenant (multi-tenant admission)
    weights: Optional[Tuple[float, float]] = None  # None → tenant default


SLO_CLASSES = ("strict", "degrade", "best_effort")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant of a multi-tenant serving deployment.

    Carries both halves of tenancy: the *client side* (an independent
    seeded arrival process — each tenant is its own open-loop stream) and
    the *server side* admission policy (preference weights for the MOO
    picks, a weighted-fair share, a priority tier, and an optional
    per-tenant solve budget overriding the server default).  UDAO-style
    cost/performance preferences are per-user by nature; the spec is where
    a user's ``weights`` live.

    ``slo`` declares what the server should do when the tenant's solve
    budget has become *unmeetable* for a waiting request (the head would
    start solving past ``arrival + budget − reserve·E[batch]``):

    * ``"best_effort"`` (default) — keep queueing; the request is served
      late (the pre-overload behavior).
    * ``"degrade"`` — admit it through the cheap compile path instead
      (template-cache-only solve / aggregated default θ, no fresh
      Algorithm 1), trading plan quality for admission latency.
    * ``"strict"`` — reject it outright (shed): the tenant prefers an
      explicit error over a blown budget, keeping its served tail inside
      the budget under overload.

    ``rate_limit_qps`` arms a per-tenant token bucket *ahead of* the
    waiting room: arrivals beyond the sustained rate (with a burst
    allowance of ``rate_limit_burst`` tokens) are rejected at the door
    with status ``"rate_limited"`` — they never enqueue, never solve, and
    never consume a batch slot.  ``None`` (default) disables the limiter.
    """
    name: str
    weights: Optional[Tuple[float, float]] = None  # None → server default
    arrivals: ArrivalModel = ArrivalModel()
    share: float = 1.0               # DRR weight within the priority tier
    priority: int = 0                # higher tiers compose first
    solve_budget_s: Optional[float] = None
    slo: str = "best_effort"         # overload policy: strict|degrade|best_effort
    rate_limit_qps: Optional[float] = None   # None → no rate limiter
    rate_limit_burst: float = 4.0            # bucket depth (tokens)

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.share <= 0:
            raise ValueError(f"share must be positive, got {self.share}")
        if self.slo not in SLO_CLASSES:
            raise ValueError(
                f"unknown SLO class {self.slo!r}; expected one of "
                f"{SLO_CLASSES}")
        if self.rate_limit_qps is not None and self.rate_limit_qps <= 0:
            raise ValueError(f"rate_limit_qps must be positive, got "
                             f"{self.rate_limit_qps}")
        if self.rate_limit_burst < 1.0:
            raise ValueError(f"rate_limit_burst must be >= 1 (a bucket that "
                             f"cannot hold one token admits nothing), got "
                             f"{self.rate_limit_burst}")


def _tenant_seed(seed: int, name: str) -> int:
    """Derived per-tenant stream seed: independent across tenant names."""
    return int(np.random.SeedSequence(
        [seed, zlib.crc32(name.encode()) & 0xFFFFFFFF]).generate_state(1)[0]
        & 0x7FFFFFFF)


def multi_tenant_stream(benchmark: str, tenants: Sequence[TenantSpec],
                        n_per_tenant, *, seed: int = 0, zipf_a: float = 1.3,
                        n_variants: int = 3, query_seed: int = 0
                        ) -> List["StreamRequest"]:
    """Merge per-tenant serving streams into one timed request stream.

    Each tenant draws its own Zipf template mix and its own arrival
    process (``spec.arrivals``) under a name-derived seed, so tenant
    populations are independent and individually reproducible; the merged
    stream is sorted by arrival time with globally unique ``rid``s.
    ``n_per_tenant`` is one count shared by all tenants or a per-tenant
    sequence aligned with ``tenants``.
    """
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    if isinstance(n_per_tenant, (int, np.integer)):
        counts = [int(n_per_tenant)] * len(tenants)
    else:
        counts = [int(n) for n in n_per_tenant]
        if len(counts) != len(tenants):
            raise ValueError(
                f"got {len(counts)} counts for {len(tenants)} tenants")
    merged: List[StreamRequest] = []
    for spec, n in zip(tenants, counts):
        reqs = serving_stream(benchmark, n, seed=_tenant_seed(seed, spec.name),
                              zipf_a=zipf_a, n_variants=n_variants,
                              arrivals=spec.arrivals, query_seed=query_seed)
        merged.extend(dataclasses.replace(r, tenant=spec.name) for r in reqs)
    merged.sort(key=lambda r: (r.arrival_s, r.tenant, r.rid))
    return [dataclasses.replace(r, rid=i) for i, r in enumerate(merged)]


def serving_stream(benchmark: str, n: int, *, seed: int = 0,
                   zipf_a: float = 1.3, n_variants: int = 3,
                   arrivals: Optional[ArrivalModel] = None,
                   query_seed: int = 0):
    """A production-like stream of ``n`` tuning requests.

    Template popularity is Zipf-distributed (rank weights ``1/r^a`` over a
    seeded template permutation) and each request picks one of
    ``n_variants`` parametric variants, variant 0 being the most common —
    the repeated-template traffic shape that lets a serving-layer
    effective-set cache amortize Algorithm 1.  Deterministic per seed.

    ``query_seed`` threads through to :func:`make_query`, so distinct query
    populations (not just distinct orderings) can be drawn reproducibly.

    Without ``arrivals`` the return value is a plain ``List[Query]`` in
    stream order (the batch-mode interface).  With an :class:`ArrivalModel`
    each request is stamped with an explicit seeded arrival time and the
    return value is a ``List[StreamRequest]`` — the streaming-admission
    interface consumed by ``repro.serve.server.OptimizerServer``.
    """
    n_t = 22 if benchmark == "tpch" else 102
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xC0FFEE]))
    rank_of = rng.permutation(n_t)
    p = 1.0 / (1.0 + np.arange(n_t, dtype=np.float64)) ** zipf_a
    p /= p.sum()
    # Variant distribution: geometric-ish, variant 0 dominant.
    pv = 0.5 ** np.arange(n_variants, dtype=np.float64)
    pv /= pv.sum()
    out: List[Query] = []
    built: Dict[Tuple[int, int], Query] = {}
    for _ in range(n):
        t = int(rank_of[rng.choice(n_t, p=p)])
        v = int(rng.choice(n_variants, p=pv))
        if (t, v) not in built:
            built[(t, v)] = make_query(benchmark, t, variant=v,
                                       seed=query_seed)
        out.append(built[(t, v)])
    if arrivals is None:
        return out
    times = arrivals.draw(n, seed)
    return [StreamRequest(rid=i, query=q, arrival_s=float(t))
            for i, (q, t) in enumerate(zip(out, times))]


def default_workload(benchmark: str, n_per_template: int = 4, *,
                     seed: int = 0) -> List[Query]:
    """Training workload: every template × ``n_per_template`` variants."""
    n_t = 22 if benchmark == "tpch" else 102
    out: List[Query] = []
    for t in range(n_t):
        out.extend(parametric_variants(benchmark, t, n_per_template,
                                       seed=seed, start=1))
    return out
