"""Jitted public wrapper for the Pareto-filter kernel.

On CPU hosts the Pallas kernel executes in interpret mode (same semantics,
Python evaluation); on TPU set ``interpret=False`` for the compiled path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import pareto_filter_pallas
from .ref import pareto_mask_ref

__all__ = ["pareto_filter", "pareto_mask_ref"]


def _default_interpret() -> bool:
    # Resolved per call, not at import: the active backend can change after
    # this module is imported (jax.default_device, distributed init, tests
    # faking a backend), and a frozen import-time answer would silently
    # interpret-mode TPU runs or try to compile on CPU.
    return jax.default_backend() != "tpu"


def pareto_filter(F: jnp.ndarray, valid: Optional[jnp.ndarray] = None,
                  *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """Boolean non-dominated mask of (n, k) minimization objectives."""
    F = jnp.asarray(F)
    if valid is None:
        valid = jnp.isfinite(F).all(-1)
    if interpret is None:
        interpret = _default_interpret()
    return pareto_filter_pallas(F, valid, interpret=interpret)
