"""Pallas TPU kernel: Pareto dominance filtering.

The O(n²k) dominance test is the inner loop of every HMOOC stage (subQ
banks, DAG merges, cross-θc filtering).  On TPU we tile the row axis: each
grid step (i, j) loads a (BI, K) block of candidate rows and a (BJ, K) block
of potential dominators into VMEM and accumulates a "dominated" flag per
candidate row with a vectorized all/any reduction over the padded objective
axis — the j axis iterates fastest so the output block for i stays resident
while all dominator blocks stream through.

Layout notes (TPU): K is padded to 8 lanes-of-sublane use and BI=BJ=128 keeps
the (BI, BJ) intermediate a single 128×128 VREG tile; all comparisons are
VPU element-wise ops (no MXU use — this kernel is bandwidth-bound, the
roofline is HBM→VMEM streaming of F at n/BJ passes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pareto_filter_pallas", "BI", "BJ", "KPAD"]

BI = 128   # candidate rows per block
BJ = 128   # dominator rows per block
KPAD = 8   # objective axis padded to 8 (sublane multiple)


def _kernel(F_i_ref, F_j_ref, vj_ref, dom_ref):
    """Grid (ni, nj): dom[i-block] |= any_j( j dominates i )."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dom_ref[...] = jnp.zeros_like(dom_ref)

    Fi = F_i_ref[...]                     # (BI, KPAD) f32
    Fj = F_j_ref[...]                     # (BJ, KPAD)
    vj = vj_ref[...]                      # (BJ, 1) f32 validity (1/0)

    # Padded objective columns hold the same value (0.0) on both sides, so
    # they compare equal and never flip the all(<=)/any(<) outcome.
    le = (Fj[:, None, :] <= Fi[None, :, :]).all(-1)    # (BJ, BI)
    lt = (Fj[:, None, :] < Fi[None, :, :]).any(-1)     # (BJ, BI)
    dominates = le & lt & (vj > 0.5)                   # (BJ, BI)
    dom_new = dominates.any(0)                         # (BI,)
    dom_ref[...] = jnp.maximum(dom_ref[...],
                               dom_new[:, None].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def pareto_filter_pallas(F: jnp.ndarray, valid: jnp.ndarray,
                         *, interpret: bool = True) -> jnp.ndarray:
    """Non-dominated mask of (n, k) objectives (minimization).

    Pads n→multiple of 128 and k→KPAD.  Invalid/padded rows are neither
    optimal nor able to dominate.  Returns bool (n,).
    """
    n, k = F.shape
    npad = (-n) % BI
    F32 = F.astype(jnp.float32)
    # Pad rows with +inf (never dominate, never optimal — masked invalid),
    # pad objective columns with 0 on BOTH sides: equal values never flip
    # the `all(<=)`/`any(<)` outcome.
    Fp = jnp.pad(F32, ((0, npad), (0, KPAD - k)), constant_values=0.0)
    Fp = Fp.at[n:, :].set(jnp.inf) if npad else Fp
    vp = jnp.pad(valid.astype(jnp.float32), (0, npad),
                 constant_values=0.0)[:, None]         # (N, 1)
    N = Fp.shape[0]
    grid = (N // BI, N // BJ)

    dom = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BI, KPAD), lambda i, j: (i, 0)),
            pl.BlockSpec((BJ, KPAD), lambda i, j: (j, 0)),
            pl.BlockSpec((BJ, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BI, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        interpret=interpret,
    )(Fp, Fp, vp)

    return (valid & (dom[:n, 0] < 0.5))
