"""Pure-jnp oracle for the Pareto dominance-filter kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pareto_mask_ref"]


def pareto_mask_ref(F: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Non-dominated mask over (n, k) minimization objectives.

    A row i is kept iff it is valid and no valid row j dominates it
    (F[j] <= F[i] element-wise with at least one strict <).
    """
    F = F.astype(jnp.float32)
    le = (F[:, None, :] <= F[None, :, :]).all(-1)     # (j, i): j <= i
    lt = (F[:, None, :] < F[None, :, :]).any(-1)
    dom = ((le & lt) & valid[:, None]).any(0)
    return valid & ~dom
