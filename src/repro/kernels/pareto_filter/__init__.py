"""Pareto dominance-filter kernel (public wrapper in ops.py)."""
from .ops import pareto_filter, pareto_mask_ref

__all__ = ["pareto_filter", "pareto_mask_ref"]
