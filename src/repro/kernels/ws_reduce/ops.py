"""Jitted public wrapper for the weighted-sum bank reduction."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import ws_reduce_pallas
from .ref import ws_reduce_ref

__all__ = ["ws_reduce", "ws_reduce_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def ws_reduce(F: jnp.ndarray, W: jnp.ndarray,
              *, interpret: Optional[bool] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted argmin over (m, B, k) banks for (nw, k) weight rows."""
    if interpret is None:
        interpret = not _ON_TPU
    return ws_reduce_pallas(jnp.asarray(F), jnp.asarray(W),
                            interpret=interpret)
