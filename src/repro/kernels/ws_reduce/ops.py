"""Jitted public wrapper for the weighted-sum bank reduction."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import ws_reduce_pallas
from .ref import ws_reduce_ref

__all__ = ["ws_reduce", "ws_reduce_ref"]


def _default_interpret() -> bool:
    # Resolved per call, not at import: the active backend can change after
    # this module is imported (jax.default_device, distributed init, tests
    # faking a backend), and a frozen import-time answer would silently
    # interpret-mode TPU runs or try to compile on CPU.
    return jax.default_backend() != "tpu"


def ws_reduce(F: jnp.ndarray, W: jnp.ndarray,
              *, interpret: Optional[bool] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted argmin over (m, B, k) banks for (nw, k) weight rows."""
    if interpret is None:
        interpret = _default_interpret()
    return ws_reduce_pallas(jnp.asarray(F), jnp.asarray(W),
                            interpret=interpret)
