"""Pallas TPU kernel: weighted-sum reduction over per-subQ solution banks.

HMOOC2's hot loop: for every weight vector w and every subQ bank F_m, find
argmin_j  w · F_m[j].  One grid step processes one subQ: the (NW, KPAD)
weight tile and the (B, KPAD) bank tile are both VMEM-resident and the score
matrix W @ F_mᵀ is a single MXU matmul — NW and B are padded to 128 so the
matmul runs at full systolic utilization; the argmin is a VPU reduction over
the lane axis.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ws_reduce_pallas", "KPAD"]

KPAD = 8


def _kernel(W_ref, F_ref, val_ref, idx_ref):
    W = W_ref[...]                                  # (NW, KPAD)
    F = F_ref[0]                                    # (B, KPAD)
    scores = jax.lax.dot_general(
        W, F, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (NW, B) MXU
    idx_ref[0] = jnp.argmin(scores, axis=-1).astype(jnp.int32)
    val_ref[0] = jnp.min(scores, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ws_reduce_pallas(F: jnp.ndarray, W: jnp.ndarray,
                     *, interpret: bool = True
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(m, B, k) banks × (nw, k) weights → (vals, idx) each (nw, m).

    Banks are padded B→multiple of 128 with +1e30 sentinels (never argmin
    unless the bank is empty) and k→KPAD with zeros (weights padded with
    zeros, so extra columns never contribute).
    """
    m, B, k = F.shape
    nw = W.shape[0]
    Bp = max(128, ((B + 127) // 128) * 128)
    NWp = max(128, ((nw + 127) // 128) * 128)
    F32 = jnp.nan_to_num(F.astype(jnp.float32), posinf=1e30)
    Fp = jnp.pad(F32, ((0, 0), (0, Bp - B), (0, KPAD - k)),
                 constant_values=0.0)
    if Bp > B:
        Fp = Fp.at[:, B:, :k].set(1e30)
    Wp = jnp.pad(W.astype(jnp.float32), ((0, NWp - nw), (0, KPAD - k)),
                 constant_values=0.0)

    vals, idx = pl.pallas_call(
        _kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((NWp, KPAD), lambda i: (0, 0)),
            pl.BlockSpec((1, Bp, KPAD), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, NWp), lambda i: (i, 0)),
            pl.BlockSpec((1, NWp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, NWp), jnp.float32),
            jax.ShapeDtypeStruct((m, NWp), jnp.int32),
        ],
        interpret=interpret,
    )(Wp, Fp)

    return vals[:, :nw].T, idx[:, :nw].T
