"""Weighted-sum bank-reduction kernel (public wrapper in ops.py)."""
from .ops import ws_reduce, ws_reduce_ref

__all__ = ["ws_reduce", "ws_reduce_ref"]
