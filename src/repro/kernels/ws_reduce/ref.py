"""Pure-jnp oracle for the weighted-sum bank reduction."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["ws_reduce_ref"]


def ws_reduce_ref(F: jnp.ndarray, W: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(weight, subQ) weighted argmin over solution banks.

    F: (m, B, k) objective banks (minimization; +inf = padded slot).
    W: (nw, k) weight vectors.
    Returns (vals (nw, m), idx (nw, m)): min weighted score and argmin index.
    """
    scores = jnp.einsum("wk,mbk->wmb", W.astype(jnp.float32),
                        F.astype(jnp.float32))
    idx = jnp.argmin(scores, axis=-1)
    vals = jnp.min(scores, axis=-1)
    return vals, idx.astype(jnp.int32)
