"""Pure-numpy oracle for the fused HMOOC2 aggregation kernel."""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["fused_ws_front_ref"]


def _local_mask_np(P: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Non-dominated mask over one candidate's (nw, k) weight picks."""
    le = (P[:, None, :] <= P[None, :, :]).all(-1)
    lt = (P[:, None, :] < P[None, :, :]).any(-1)
    dom = ((le & lt) & v[:, None]).any(0)
    return v & ~dom


def fused_ws_front_ref(Fn: np.ndarray, F_bank: np.ndarray, W: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Reference :func:`..fused_solve.fused_ws_front`: same shapes, same
    mixed-precision contract, no padding and no jit.

    Weighted-sum scores and the global dominance compare run in float32
    (the kernel regime's documented semantics); the objective-sum gather
    and the per-candidate dominance mask keep float64.
    """
    N, m, B, k = F_bank.shape
    nw = W.shape[0]
    scores = np.einsum("wk,cmbk->cwmb", W.astype(np.float32),
                       np.asarray(Fn, np.float32))         # (N, nw, m, B)
    jj = np.argmin(scores, axis=-1).astype(np.int32)       # (N, nw, m)
    cc = np.arange(N)[:, None, None]
    ii = np.arange(m)[None, None, :]
    G = np.asarray(F_bank, np.float64)[cc, ii, jj]         # (N, nw, m, k)
    P_all = G.sum(axis=2)                                  # (N, nw, k)
    ok = np.isfinite(G).all(axis=(2, 3))                   # (N, nw)
    local = np.stack([_local_mask_np(P_all[c], ok[c]) for c in range(N)])
    P32 = P_all.reshape(N * nw, k).astype(np.float32)
    v = (ok & local).reshape(-1)
    le = (P32[:, None, :] <= P32[None, :, :]).all(-1)
    lt = (P32[:, None, :] < P32[None, :, :]).any(-1)
    dom = ((le & lt) & v[:, None]).any(0)
    keep = (v & ~dom).reshape(N, nw)
    return jj, P_all, keep
