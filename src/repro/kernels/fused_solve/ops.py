"""Fused HMOOC2 aggregation: ws_reduce + pareto_filter in one compiled solve.

The kernel-regime HMOOC2 path used to make three round-trips per
aggregation: the ``ws_reduce`` argmin picks, a host-side gather/sum of the
picked bank rows, and per-candidate + global dominance masks through
``pareto_filter``.  :func:`fused_ws_front` composes all of it under a single
``jax.jit``: one MXU weighted-sum reduction, the objective-sum gather, the
per-candidate dominance mask over the weight picks, and the final global
Pareto filter across every (candidate, weight) point — with the padded input
buffers donated to XLA on accelerator backends.

Shape policy: the candidate axis N and the subQ axis m are padded to
power-of-two buckets (tracked in :data:`SEEN_BUCKETS`), so a serving session
compiles O(log N_max · log m_max) signatures however query shapes vary.
Padded candidates carry +inf banks (never valid); padded subQs carry
all-zero banks (their picks contribute zero to every objective sum and are
sliced off before returning).

Numerical semantics match the pre-fusion kernel regime: weighted-sum scores
and the global dominance compare in float32 (the usual Pallas-kernel tie
caveat), objective sums and the per-candidate mask keep float64.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..pareto_filter.kernel import pareto_filter_pallas
from ..ws_reduce.kernel import ws_reduce_pallas

__all__ = ["fused_ws_front", "SEEN_BUCKETS"]

# (N bucket, m bucket, B, k, nw) signatures dispatched so far — the
# recompilation-bound benchmarks assert this stays ≤ the bucket count.
SEEN_BUCKETS: set = set()


def _pow2(n: int, lo: int) -> int:
    return max(lo, 1 << (max(n, 1) - 1).bit_length())


def _local_mask(P: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Non-dominated mask over one candidate's (nw, k) weight picks."""
    le = (P[:, None, :] <= P[None, :, :]).all(-1)
    lt = (P[:, None, :] < P[None, :, :]).any(-1)
    dom = ((le & lt) & v[:, None]).any(0)
    return v & ~dom


def _fused_impl(Fn, Fb, W, *, interpret: bool):
    Np, mp, B, k = Fn.shape
    nw = W.shape[0]
    # One MXU pass over every (candidate, subQ) bank.
    _, idx = ws_reduce_pallas(Fn.reshape(Np * mp, B, k), W,
                              interpret=interpret)        # (nw, Np*mp)
    jj = idx.T.reshape(Np, mp, nw).transpose(0, 2, 1)     # (Np, nw, mp)
    cc = jnp.arange(Np)[:, None, None]
    ii = jnp.arange(mp)[None, None, :]
    G = Fb[cc, ii, jj]                                    # (Np, nw, mp, k)
    P_all = G.sum(axis=2)                                 # (Np, nw, k)
    ok = jnp.isfinite(G).all(axis=(2, 3))                 # (Np, nw)
    local = jax.vmap(_local_mask)(P_all, ok)
    keep = pareto_filter_pallas(
        P_all.reshape(Np * nw, k).astype(jnp.float32),
        (ok & local).reshape(-1), interpret=interpret).reshape(Np, nw)
    return jj, P_all, keep


_fused = jax.jit(_fused_impl, static_argnames=("interpret",))
# Padded buffers are single-use: donate them on accelerator backends.
_fused_donated = jax.jit(_fused_impl, static_argnames=("interpret",),
                         donate_argnums=(0, 1))


def fused_ws_front(Fn: np.ndarray, F_bank: np.ndarray, W: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(N, m, B, k) normalized scores + raw banks + (nw, k) weights →
    (jj (N, nw, m) picks, P_all (N, nw, k) objective sums, keep (N, nw)).

    ``keep`` composes validity, the per-candidate dominance mask over the
    weight picks, and the global Pareto filter across all candidates —
    ``P_all[keep]`` is the query-level front, already globally filtered.
    """
    N, m, B, k = F_bank.shape
    nw = W.shape[0]
    Np, mp = _pow2(N, 32), _pow2(m, 4)
    SEEN_BUCKETS.add((Np, mp, B, k, nw))
    Fnp = np.zeros((Np, mp, B, k), np.float32)
    Fnp[:N, :m] = Fn
    Fnp[N:] = 1e18
    Fbp = np.zeros((Np, mp, B, k), np.float64)
    Fbp[:N, :m] = F_bank
    Fbp[N:] = np.inf
    on_cpu = jax.default_backend() == "cpu"
    fn = _fused if on_cpu else _fused_donated
    with jax.experimental.enable_x64():
        jj, P_all, keep = fn(jnp.asarray(Fnp), jnp.asarray(Fbp),
                             jnp.asarray(W, jnp.float32),
                             interpret=on_cpu)
    return (np.asarray(jj)[:N, :, :m], np.asarray(P_all)[:N],
            np.asarray(keep)[:N])
