from .ops import fused_ws_front, SEEN_BUCKETS
from .ref import fused_ws_front_ref

__all__ = ["fused_ws_front", "fused_ws_front_ref", "SEEN_BUCKETS"]
