from .ops import fused_ws_front, SEEN_BUCKETS

__all__ = ["fused_ws_front", "SEEN_BUCKETS"]
