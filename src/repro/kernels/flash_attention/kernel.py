"""Pallas TPU kernel: flash attention with GQA and causal masking.

Online-softmax tiling (Dao et al.) adapted to the TPU memory hierarchy:

* Q tiles of (BQ, D) stay VMEM-resident for a full sweep over KV tiles of
  (BK, D); the running max/denominator and the (BQ, D) f32 accumulator live
  in VMEM scratch, so HBM traffic is one read of Q/K/V and one write of O.
* BQ = BK = 128 and D padded to a 128 multiple keep the two matmuls per
  step (Q·Kᵀ and P·V) MXU-shaped.
* GQA is resolved in the BlockSpec index map — query-head b reads KV head
  b→(b // group) without materializing repeated KV (saves Hq/Hkv × KV HBM
  traffic, the reason GQA exists).
* Causal masking skips KV tiles strictly above the diagonal via
  ``pl.when`` so the wasted-FLOP fraction is ≤ BK/Skv.

Inputs are pre-collapsed to (BH, S, D) by ``ops.py``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas", "BQ", "BK"]

BQ = 128
BK = 128
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, nk: int, sq: int, skv: int,
            skv_real: int):
    i = pl.program_id(1)       # q block
    j = pl.program_id(2)       # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    offset = skv - sq          # causal alignment: query t sees keys ≤ t+offset
    run = True
    if causal:
        # KV block j is fully masked iff its first key > last query + offset.
        run = (j * BK) <= (i * BQ + BQ - 1) + offset

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (BQ, D)
        k = k_ref[0].astype(jnp.float32)            # (BK, D)
        v = v_ref[0].astype(jnp.float32)            # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (BQ, BK)
        kj = j * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        if skv_real < nk * BK:
            s = jnp.where(kj < skv_real, s, NEG)           # mask padded keys
        if causal:
            qi = i * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
            s = jnp.where(kj <= qi + offset, s, NEG)
        m_prev = m_ref[...]                          # (BQ, 1)
        m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_cur)                       # (BQ, BK)
        alpha = jnp.exp(m_prev - m_cur)              # (BQ, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "group", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, group: int = 1,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (BHq, Sq, D); k, v: (BHkv, Skv, D); query head b uses kv head
    b // group.  Returns (BHq, Sq, D)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / (D ** 0.5)
    # Pad sequence axes to block multiples; padded keys are masked by the
    # softmax running max only if they can win — guard with explicit -inf
    # via causal offset for queries, and pad K rows with zeros + rely on
    # the padded-query rows being discarded on slice-out.
    sq_pad = (-Sq) % BQ
    sk_pad = (-Skv) % BK
    qp = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0)))
    SqP, SkP = Sq + sq_pad, Skv + sk_pad
    nq, nk = SqP // BQ, SkP // BK

    # Causal alignment uses REAL lengths (query t sees keys ≤ t + offset);
    # padded key columns are masked inside the kernel via skv_real.
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               nk=nk, sq=Sq, skv=Skv, skv_real=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, BQ, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, D), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, BK, D), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, SqP, D), q.dtype),
        scratch_shapes=[
            # f32 VMEM scratch: accumulator + running max + denominator
            pltpu.VMEM((BQ, D), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq, :]
