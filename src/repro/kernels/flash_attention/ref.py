"""Pure-jnp oracle for flash attention (GQA + causal)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True) -> jnp.ndarray:
    """Reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q.dtype; accumulation in f32.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / jnp.sqrt(D)
    if causal:
        Skv = k.shape[2]
        # Align the ends: query i attends keys <= i + (Skv - Sq).
        qi = jnp.arange(Sq)[:, None]
        kj = jnp.arange(Skv)[None, :]
        mask = kj <= qi + (Skv - Sq)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jnp.exp(logits - logits.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vf)
    return out.astype(q.dtype)
