"""Public flash-attention wrapper: (B, H, S, D) API with GQA."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]


def _default_interpret() -> bool:
    # Resolved per call, not at import: the active backend can change after
    # this module is imported (jax.default_device, distributed init, tests
    # faking a backend), and a frozen import-time answer would silently
    # interpret-mode TPU runs or try to compile on CPU.
    return jax.default_backend() != "tpu"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Flash attention with grouped-query heads.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D), Hq % Hkv == 0.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if interpret is None:
        interpret = _default_interpret()
    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, k.shape[2], D)
    vf = v.reshape(B * Hkv, v.shape[2], D)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, group=group,
                                 interpret=interpret)
    return out.reshape(B, Hq, Sq, D)
