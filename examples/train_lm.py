"""End-to-end driver: train a small LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--m100]

Default trains a ~6M-parameter minicpm-family model (CPU box); ``--m100``
scales to ~100M parameters (the deliverable scale — hours on CPU, minutes
on one TPU host).  Demonstrates: data pipeline → pjit train step (WSD
AdamW, grad accumulation) → checkpoint/restart → elastic restore.
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.archs.registry import build_model, get_smoke_config
from repro.data.pipeline import data_iterator
from repro.launch.mesh import make_host_mesh
from repro.train.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.train.optimizer import OptConfig
from repro.train.train_loop import make_train_step, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--m100", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config("minicpm-2b")
    if args.m100:
        cfg = cfg.with_(n_layers=8, d_model=512, n_heads=8, n_kv=8,
                        d_head=64, d_ff=1408, vocab=64000)
    api = build_model(cfg)
    mesh = make_host_mesh()
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(
        jax.eval_shape(api.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"mesh {mesh.devices.shape}")

    opt = OptConfig(lr=3e-3, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 1))
    it = data_iterator(cfg, global_batch=args.batch, seq_len=args.seq)
    t0 = time.time()
    out = train_loop(api, mesh, it, steps=args.steps, opt_cfg=opt,
                     checkpoint_dir=args.ckpt,
                     checkpoint_every=max(args.steps // 2, 1))
    hist = out["history"]
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps in {dt:.1f}s ({toks/dt:.0f} tok/s)")
    print(f"loss {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f}")

    # Restart-from-checkpoint demonstration (fault tolerance).
    step = latest_step(args.ckpt)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        {"params": out["params"], "opt": out["opt_state"]})
    restored, at = restore_checkpoint(args.ckpt, like)
    print(f"restored checkpoint at step {at} "
          f"({len(jax.tree_util.tree_leaves(restored))} tensors) — "
          f"restart path verified")


if __name__ == "__main__":
    main()
