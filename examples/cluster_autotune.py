"""Beyond-paper: HMOOC tunes the training cluster itself.

    PYTHONPATH=src python examples/cluster_autotune.py [--arch qwen2-72b]

θc = (chips, TP split, moment dtype, carry sharding), θp per layer block
(remat / attention impl / MoE capacity), θs = (accum, unroll).  The Pareto
front trades step latency against $ per step; WUN picks per preference.
"""
import argparse

import numpy as np

from repro.cluster.autotune import autotune


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    print(f"autotuning {args.arch} × {args.shape}\n")
    for w in [(0.95, 0.05), (0.7, 0.3), (0.5, 0.5), (0.3, 0.7),
              (0.05, 0.95)]:
        plan = autotune(args.arch, args.shape, weights=w)
        print(f"w(lat,cost)=({w[0]:.2f},{w[1]:.2f}) → {plan.summary()}")
        for block, tp in plan.theta_p.items():
            ts = plan.theta_s[block]
            print(f"    {block:10s} remat={int(tp['remat'])} "
                  f"chunked_attn={int(tp['chunked_attn'])} "
                  f"cap={tp['capacity_factor']:.2f} "
                  f"accum={int(ts['accum'])} unroll={int(ts['unroll'])}")

    plan = autotune(args.arch, args.shape, weights=(0.5, 0.5))
    F = plan.front[np.argsort(plan.front[:, 0])]
    print("\nPareto front (latency s, $/step):")
    for row in F:
        print(f"  {row[0]:8.2f}  {row[1]:.5f}")


if __name__ == "__main__":
    main()
