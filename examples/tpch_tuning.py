"""Workload tuning: all 22 TPC-H queries, HMOOC3+ vs default (Table 4 style).

    PYTHONPATH=src python examples/tpch_tuning.py [--model]

``--model`` uses the trained GTN models (trains/caches them on first use —
minutes); default uses oracle objectives for a fast demonstration.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, ".")  # for benchmarks.* when run from the repo root

from repro.core.moo.hmooc import HMOOCConfig
from repro.core.tuning.compile_time import compile_time_optimize
from repro.core.tuning.runtime import make_runtime_optimizers
from repro.queryengine.aqe import run_with_aqe
from repro.queryengine.simulator import default_theta
from repro.queryengine.workloads import make_benchmark


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", action="store_true")
    ap.add_argument("--weights", default="0.9,0.1")
    args = ap.parse_args()
    w = tuple(float(x) for x in args.weights.split(","))

    model = None
    if args.model:
        from benchmarks.common import get_model
        model = get_model("tpch", "subq")[0]

    lat_d, lat_o, st = [], [], []
    for q in make_benchmark("tpch"):
        tc, tp, ts = default_theta(1)
        base = run_with_aqe(q, tc[0], tp[0], ts[0])
        ct = compile_time_optimize(q, model=model, weights=w,
                                   cfg=HMOOCConfig(dag_method="hmooc3"))
        lqp_o, qs_o = make_runtime_optimizers(
            q, ct.theta_c, seed_theta_p=ct.theta_p_sub,
            seed_theta_s=ct.theta_s_sub, model_subq=model, model_qs=model,
            weights=w)
        opt = run_with_aqe(q, ct.theta_c, ct.theta_p0, ct.theta_s0,
                           lqp_optimizer=lqp_o, qs_optimizer=qs_o)
        lat_d.append(base.sim.actual_latency[0])
        lat_o.append(opt.sim.actual_latency[0])
        st.append(ct.solve_time)
        red = 1 - lat_o[-1] / lat_d[-1]
        print(f"{q.qid}: {lat_d[-1]:7.2f}s → {lat_o[-1]:7.2f}s "
              f"({red:+.0%})  solve {st[-1]:.2f}s")

    lat_d, lat_o = np.array(lat_d), np.array(lat_o)
    print(f"\ntotal latency reduction: "
          f"{1 - lat_o.sum() / lat_d.sum():.0%} "
          f"(avg per-query {np.mean(1 - lat_o / lat_d):.0%}); "
          f"solve time avg {np.mean(st):.2f}s max {np.max(st):.2f}s")


if __name__ == "__main__":
    main()
