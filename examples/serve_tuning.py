"""Batched compile-time tuning service demo.

Feeds a Zipf-distributed repeated-template request stream through a
long-lived :class:`repro.serve.TuningService` and prints per-batch
throughput plus cache behavior — the serving regime behind the paper's
1–2 s per-query cloud budget.

Run:  PYTHONPATH=src python examples/serve_tuning.py --bench tpch --batch 16
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.moo.hmooc import HMOOCConfig
from repro.queryengine.workloads import serving_stream
from repro.serve import TuningService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="tpch", choices=["tpch", "tpcds"])
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    stream = serving_stream(args.bench, args.n_requests, seed=args.seed)
    svc = TuningService(cfg=HMOOCConfig(seed=args.seed))
    weights = (0.9, 0.1)

    for lo in range(0, len(stream), args.batch):
        batch = stream[lo:lo + args.batch]
        results = svc.tune_batch(batch, weights)
        st = svc.last_batch
        lat = np.array([r.chosen_objectives[0] for r in results])
        print(f"batch {lo // args.batch}: {st.n_queries} queries "
              f"({st.n_solved} solved, {st.n_deduped} served from cache) "
              f"in {st.wall_time:.2f}s = {st.qps:.1f} q/s | "
              f"mean believed latency {lat.mean():.1f}s")
    print("effective-set cache:", svc.cache.stats())


if __name__ == "__main__":
    main()
