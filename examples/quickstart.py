"""Quickstart: tune one TPC-H query with HMOOC3, end to end.

    PYTHONPATH=src python examples/quickstart.py

Builds the query, solves the compile-time MOO (oracle objectives — no
model training needed), aggregates the submission θp/θs, executes under
AQE with runtime re-optimization, and prints the before/after.
"""
import numpy as np

from repro.core.moo.hmooc import HMOOCConfig
from repro.core.tuning.compile_time import compile_time_optimize
from repro.core.tuning.runtime import make_runtime_optimizers
from repro.queryengine.aqe import run_with_aqe
from repro.queryengine.simulator import default_theta
from repro.queryengine.workloads import make_benchmark


def main() -> None:
    query = make_benchmark("tpch")[18]         # a long-running join query
    print(f"query {query.qid}: {query.n_subqs} subQs, "
          f"{len(query.ops)} operators")

    # --- default Spark configuration -------------------------------------
    tc, tp, ts = default_theta(1)
    base = run_with_aqe(query, tc[0], tp[0], ts[0])
    print(f"default:   latency {base.sim.actual_latency[0]:8.2f} s   "
          f"cost ${base.sim.cost[0]:.4f}")

    # --- compile-time optimization (θc* + fine-grained θp/θs) -------------
    ct = compile_time_optimize(query, weights=(0.9, 0.1),
                               cfg=HMOOCConfig(dag_method="hmooc3"))
    print(f"HMOOC3 solved in {ct.solve_time:.2f}s: "
          f"{ct.front.shape[0]} Pareto points; picked "
          f"cores={ct.theta_c[0]:.0f}×{ct.theta_c[2]:.0f} "
          f"mem={ct.theta_c[1]:.0f}GB")

    opt = run_with_aqe(query, ct.theta_c, ct.theta_p0, ct.theta_s0)
    print(f"HMOOC3:    latency {opt.sim.actual_latency[0]:8.2f} s   "
          f"cost ${opt.sim.cost[0]:.4f}")

    # --- + runtime optimization (AQE plugin) ------------------------------
    lqp_o, qs_o = make_runtime_optimizers(
        query, ct.theta_c, seed_theta_p=ct.theta_p_sub,
        seed_theta_s=ct.theta_s_sub, weights=(0.9, 0.1))
    rt = run_with_aqe(query, ct.theta_c, ct.theta_p0, ct.theta_s0,
                      lqp_optimizer=lqp_o, qs_optimizer=qs_o)
    print(f"HMOOC3+:   latency {rt.sim.actual_latency[0]:8.2f} s   "
          f"cost ${rt.sim.cost[0]:.4f}   "
          f"({rt.requests_sent}/{rt.requests_total} runtime requests after "
          f"pruning)")

    red = 1 - rt.sim.actual_latency[0] / base.sim.actual_latency[0]
    print(f"\nlatency reduction vs default: {red:.0%}")


if __name__ == "__main__":
    main()
